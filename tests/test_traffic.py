"""Open-loop traffic generators: seeded determinism, legacy
bit-compatibility, arrival-process shape, heavy-tail sampling, and the
loud id+field request validation errors.  Pure numpy — no model, no jit.
"""
import numpy as np
import pytest

from repro.serve import (ClosedLoop, Diurnal, FlashCrowd, LengthModel,
                         Poisson, Request, synthetic_workload,
                         validate_requests, with_deadlines)
from repro.serve.traffic import bounded_pareto


def _legacy_synthetic(vocab_size, n_requests, rng, *, min_prompt=4,
                      max_prompt=20, min_new=3, max_new=10,
                      arrival_every=2, per_arrival=1):
    """Verbatim copy of the pre-traffic-layer builder: the draw-order
    contract ClosedLoop must keep."""
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab_size,
                                        size=int(rng.integers(
                                            min_prompt, max_prompt + 1))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(min_new, max_new + 1)),
                    arrival=(i // per_arrival) * arrival_every)
            for i in range(n_requests)]


def test_synthetic_workload_bit_identical_to_legacy():
    old = _legacy_synthetic(331, 24, np.random.default_rng(5),
                            per_arrival=2, max_prompt=17)
    new = synthetic_workload(331, 24, np.random.default_rng(5),
                             per_arrival=2, max_prompt=17)
    # the old import path must keep working too
    from repro.serve.engine import synthetic_workload as engine_sw
    shim = engine_sw(331, 24, np.random.default_rng(5), per_arrival=2,
                     max_prompt=17)
    for variant in (new, shim):
        assert [r.rid for r in variant] == [r.rid for r in old]
        for a, b in zip(old, variant):
            np.testing.assert_array_equal(a.prompt, b.prompt)
            assert a.max_new_tokens == b.max_new_tokens
            assert a.arrival == b.arrival


def test_closed_loop_is_degenerate_arrival_process():
    wl = ClosedLoop(n_requests=9, arrival_every=3, per_arrival=2,
                    lengths=LengthModel(vocab_size=100))
    reqs = wl.build(0)
    assert [r.arrival for r in reqs] == [0, 0, 3, 3, 6, 6, 9, 9, 12]
    assert all(r.arrival_time is None and r.deadline is None
               for r in reqs)


@pytest.mark.parametrize("wl", [
    Poisson(n_requests=40, rate=12.0),
    Diurnal(n_requests=40, base_rate=2.0, peak_rate=25.0, period_s=5.0),
    FlashCrowd(n_requests=40, base_rate=4.0, burst_factor=10.0,
               burst_start_s=1.0, burst_dur_s=1.0),
])
def test_open_loop_arrivals_sorted_deterministic(wl):
    a = wl.build(3)
    b = wl.build(3)
    c = wl.build(4)
    ts = [r.arrival_time for r in a]
    assert all(t is not None and t >= 0 for t in ts)
    assert ts == sorted(ts)
    assert ts == [r.arrival_time for r in b]          # same seed replays
    assert ts != [r.arrival_time for r in c]          # seeds matter
    # arrival process and length draws are independent streams in order:
    # lengths depend only on (seed, n), not on which process ran first
    assert [len(r.prompt) for r in a] == [len(r.prompt) for r in b]


def test_poisson_rate_scaling():
    fast = Poisson(n_requests=300, rate=50.0).build(0)
    slow = Poisson(n_requests=300, rate=5.0).build(0)
    assert fast[-1].arrival_time < slow[-1].arrival_time / 5


def test_flash_crowd_concentrates_arrivals():
    wl = FlashCrowd(n_requests=200, base_rate=4.0, burst_factor=12.0,
                    burst_start_s=2.0, burst_dur_s=1.0)
    ts = np.asarray([r.arrival_time for r in wl.build(1)])
    in_burst = np.sum((ts >= 2.0) & (ts < 3.0))
    before = np.sum(ts < 2.0)
    # ~12x the base intensity inside the 1s window vs 2s of baseline
    assert in_burst > 3 * before


def test_diurnal_peak_density():
    wl = Diurnal(n_requests=400, base_rate=1.0, peak_rate=30.0,
                 period_s=8.0)
    ts = np.asarray([r.arrival_time for r in wl.build(2)])
    phase = np.mod(ts, 8.0)
    near_peak = np.sum(np.abs(phase - 4.0) < 2.0)   # middle half-period
    off_peak = np.sum(np.abs(phase - 4.0) >= 2.0)
    assert near_peak > 2 * off_peak


def test_bounded_pareto_bounds_and_tail():
    rng = np.random.default_rng(0)
    xs = [bounded_pareto(rng, 4, 256, 1.2) for _ in range(3000)]
    assert min(xs) >= 4 and max(xs) <= 256
    # heavy tail: median well below the midpoint, but the max gets close
    # to the cap
    assert np.median(xs) < 30
    assert max(xs) > 128


def test_length_model_clamp_and_deadlines():
    lm = LengthModel(vocab_size=50, min_prompt=4, max_prompt=30,
                     min_new=2, max_new=40, dist="pareto", clamp_len=32)
    wl = Poisson(n_requests=100, rate=10.0, lengths=lm, slack_s=2.0,
                 slack_per_token_s=0.1)
    reqs = wl.build(6)
    for r in reqs:
        assert len(r.prompt) + r.max_new_tokens <= 32
        assert r.max_new_tokens >= 1
        assert r.deadline == pytest.approx(
            r.arrival_time + 2.0 + 0.1 * r.max_new_tokens)
    validate_requests(reqs, 32)      # engine-admissible as built
    with pytest.raises(ValueError):
        LengthModel(vocab_size=50, dist="cauchy")


def test_with_deadlines_helper():
    reqs = ClosedLoop(n_requests=4,
                      lengths=LengthModel(vocab_size=20)).build(0)
    out = with_deadlines(reqs, slack_s=1.5, slack_per_token_s=0.5)
    for r in out:
        assert r.deadline == pytest.approx(
            1.5 + 0.5 * r.max_new_tokens)


def test_validation_names_request_and_field():
    ok = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match=r"request 7.*field 'deadline'"):
        validate_requests([ok, Request(rid=7,
                                       prompt=np.zeros(4, np.int32),
                                       max_new_tokens=2, deadline=-3.0)],
                          16)
    with pytest.raises(ValueError, match=r"request 8.*field 'deadline'"
                                         r".*expire before it arrives"):
        validate_requests([Request(rid=8, prompt=np.zeros(4, np.int32),
                                   max_new_tokens=2, arrival_time=4.0,
                                   deadline=4.0)], 16)
    with pytest.raises(ValueError,
                       match=r"request 9.*field 'arrival_time'"):
        validate_requests([Request(rid=9, prompt=np.zeros(4, np.int32),
                                   max_new_tokens=2,
                                   arrival_time=float("nan"))], 16)
    with pytest.raises(ValueError, match=r"request 2.*field 'arrival'"):
        validate_requests([Request(rid=2, prompt=np.zeros(4, np.int32),
                                   max_new_tokens=2, arrival=-1)], 16)
    with pytest.raises(ValueError,
                       match=r"request 1.*field 'max_new_tokens'"):
        validate_requests([Request(rid=1, prompt=np.zeros(4, np.int32),
                                   max_new_tokens=0)], 16)
    # a deadline with no arrival_time counts from t=0
    with pytest.raises(ValueError, match=r"request 3.*field 'deadline'"):
        validate_requests([Request(rid=3, prompt=np.zeros(4, np.int32),
                                   max_new_tokens=2, deadline=0.0)], 16)
