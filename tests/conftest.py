import os

# Smoke tests and benches must see ONE device; only the dry-run (run as a
# subprocess / module entry) forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
