import os
import sys

# Smoke tests and benches must see ONE device; only the dry-run (run as a
# subprocess / module entry) forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Make tests/ importable from every test dir (incl. tests/kernels/) so the
# shared _hypothesis_compat shim is a single module, not nine copies; the
# repo root rides along so tests can drive the benchmarks package (the
# fleet acceptance test reuses the fleet_bench scenario).
_here = os.path.dirname(__file__)
for _p in (_here, os.path.dirname(_here)):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
