"""Multi-host fleet runtime: topology/view/event-log units + the
2-process ``jax.distributed`` acceptance test.

The subprocess test is the ISSUE-3 acceptance scenario: two CPU
processes initialize one ``jax.distributed`` runtime, each owns half of
a 4-device fleet, and a device fault observed ONLY by process 0 travels
through the shared ordered event log — both processes fold the same
FleetPlan, the faulted device's in-flight work re-admits on the hot
spare owned by process 1, and the merged completions are bit-identical
to the single-host reference decode.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ----------------------------------------------------- in-process units
def test_host_topology_translation():
    from repro.launch.distributed import HostTopology

    topo = HostTopology(num_hosts=3, devices_per_host=2, host_id=1)
    assert topo.n_devices == 6
    assert topo.host_of(0) == 0 and topo.host_of(5) == 2
    assert topo.devices_of() == (2, 3)              # this host's block
    assert topo.devices_of(2) == (4, 5)
    assert topo.local_index(3) == 1
    assert topo.global_index(2, 1) == 5
    assert topo.is_local(2) and not topo.is_local(4)
    with pytest.raises(ValueError):
        topo.host_of(6)
    with pytest.raises(ValueError):
        HostTopology(num_hosts=2, devices_per_host=2, host_id=2)


def test_host_view_slices_and_shard_bounds():
    from repro.core.routing import FleetPlan
    from repro.launch.distributed import HostTopology, HostView

    fp = FleetPlan.healthy(4, ["flash_attention"], n_spares=1)
    fp = fp.with_device_fault(0)                    # migrates to spare 3
    view = HostView.of(fp, HostTopology(2, 2, host_id=0))
    assert view.mask == (False, True, True, True)
    assert view.host_mask(0) == (False, True)
    assert view.serving_on(1) == (2, 3)
    assert view.hosts_serving() == (0, 1)
    assert view.local_serving() == (1,)
    # global split, local slice: host 0 serves 1 of 3 devices
    bounds = view.shard_bounds(9)
    assert set(bounds) == {1} and bounds[1] == (0, 3)
    with pytest.raises(ValueError):
        HostView.of(fp, HostTopology(3, 2, host_id=0))  # 6 != 4 devices


def test_host_view_local_devices_emulation_vs_partitioned(monkeypatch):
    """Regression: in single-process emulation (host_id=None) the
    logical->physical mapping is identity — translating through
    local_index would alias host blocks onto the same devices."""
    import jax

    from repro.core.routing import FleetPlan
    from repro.launch.distributed import HostTopology, HostView

    fake = ["d0", "d1", "d2", "d3"]
    monkeypatch.setattr(jax, "local_devices", lambda: fake)
    fp = FleetPlan.healthy(4, ["flash_attention"], n_spares=0)
    emu = HostView.of(fp, HostTopology(2, 2, host_id=None))
    assert emu.local_serving_devices() == ["d0", "d1", "d2", "d3"]
    # a partitioned host maps its block onto its OWN local device slots
    h1 = HostView.of(fp, HostTopology(2, 2, host_id=1))
    assert h1.local_serving_devices() == ["d0", "d1"]
    monkeypatch.setattr(jax, "local_devices", lambda: ["d0"])
    with pytest.raises(RuntimeError, match="short"):
        h1.local_serving_devices()


def test_event_log_merge_is_canonical():
    from repro.launch.distributed import FleetEvent, merge_event_logs

    a = [FleetEvent(3, 0, 0, "device", 1), FleetEvent(5, 0, 1, "recover",
                                                      1)]
    b = [FleetEvent(3, 1, 0, "stage", 2, "flash_attention")]
    merged = merge_event_logs(a, b)
    assert merged == merge_event_logs(b, list(reversed(a)))   # any order
    assert merged == merge_event_logs(merged, a)              # idempotent
    assert [e.step for e in merged] == [3, 3, 5]
    assert merged[0].origin == 0                   # (step, origin, seq)
    wire = [e.to_wire() for e in merged]
    assert tuple(FleetEvent.from_wire(w) for w in wire) == merged
    with pytest.raises(ValueError):
        FleetEvent(0, 0, 0, "melted", 1)
    with pytest.raises(ValueError):
        FleetEvent(0, 0, 0, "stage", 1)            # stage name required


def test_shadow_workers_replay_remote_schedule(setup_fleet_model):
    """Host-scoped slot pools without a coordinator: remote devices are
    bookkeeping shadows — the global schedule (admissions, capacity,
    steps) is identical, local devices produce real tokens, remote
    completions are placeholders awaiting the merge."""
    from repro.launch.distributed import HostTopology
    from repro.serve import (FleetConfig, FleetServeEngine, ServeConfig,
                             synthetic_workload)

    cfg, params = setup_fleet_model
    rng = np.random.default_rng(0)
    reqs = synthetic_workload(cfg.vocab_size, 6, rng, min_prompt=6,
                              max_prompt=8, min_new=4, max_new=7,
                              arrival_every=1, per_arrival=2)
    runs = {}
    for host_id in (None, 0, 1):
        topo = HostTopology(2, 2, host_id=host_id)
        eng = FleetServeEngine(
            cfg, params, ServeConfig(max_len=48, max_slots=2),
            FleetConfig(n_devices=4, n_spares=0, topology=topo))
        runs[host_id] = eng.serve(list(reqs))
    full_done, full_stats = runs[None]
    for host_id in (0, 1):
        done, stats = runs[host_id]
        assert sorted(done) == sorted(full_done)           # same schedule
        assert stats["per_step_tokens"] == full_stats["per_step_tokens"]
        for rid, c in done.items():
            assert c.device == full_done[rid].device       # same placement
            assert c.placeholder == (c.device // 2 != host_id)
            if not c.placeholder:                          # local = real
                np.testing.assert_array_equal(c.tokens,
                                              full_done[rid].tokens)
    # the two half-fleets together cover every request with real tokens
    owners = {rid: {h for h in (0, 1) if not runs[h][0][rid].placeholder}
              for rid in full_done}
    assert all(len(v) == 1 for v in owners.values())


@pytest.fixture(scope="module")
def setup_fleet_model():
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen1.5-4b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------- 2-process acceptance
WORKER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    # Pin the CPU backend (the TPU probe burns minutes off-TPU).
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json
    import numpy as np
    pid, port = int(sys.argv[1]), sys.argv[2]
    from repro.launch.distributed import (HostTopology, KVCoordinator,
                                          fleet_fingerprint,
                                          initialize_runtime)
    rt = initialize_runtime(f"127.0.0.1:{port}", num_processes=2,
                            process_id=pid)
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import (FleetConfig, FleetServeEngine, ServeConfig,
                             reference_decode, synthetic_workload)

    cfg = get_config("qwen1.5-4b").reduced()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    topo = HostTopology(num_hosts=2, devices_per_host=2,
                        host_id=rt.process_id)
    coord = KVCoordinator()
    # host 0: workers 0,1; host 1: worker 2 + hot spare 3
    eng = FleetServeEngine(
        cfg, params, ServeConfig(max_len=48, max_slots=2),
        FleetConfig(n_devices=4, n_spares=1, topology=topo),
        coordinator=coord)
    reqs = synthetic_workload(cfg.vocab_size, 6, np.random.default_rng(0),
                              min_prompt=6, max_prompt=8, min_new=4,
                              max_new=7, arrival_every=1, per_arrival=2)
    # ONLY process 0 observes the fault; the shared ordered event log
    # must carry it to process 1
    events = {3: [("device", 0)]} if rt.process_id == 0 else {}
    done, stats = eng.serve(reqs, events=events)
    mismatched = []
    for r in reqs:
        ref = reference_decode(cfg, params, r.prompt, r.max_new_tokens,
                               max_len=48)
        if not np.array_equal(done[r.rid].tokens, ref):
            mismatched.append(r.rid)
    out = {
        "pid": rt.process_id,
        "n_global_devices": len(jax.devices()),
        "fingerprints": coord.exchange(fleet_fingerprint(eng.fleet)),
        "quarantined": list(eng.fleet.quarantined),
        "spare_for_0": eng.fleet.pool.spare_for(0),
        "completed": sorted(done),
        "devices_by_rid": {str(rid): done[rid].device
                           for rid in sorted(done)},
        "mismatched": mismatched,
        "requeued": stats["requeued"],
        "late_events": stats["late_events"],
        "per_device_tokens": stats["per_device_tokens"],
        "fleet_fingerprint": stats["fleet_fingerprint"],
    }
    # the distributed backend really computes across processes (gloo)
    try:
        from jax.experimental import multihost_utils
        g = multihost_utils.process_allgather(
            np.asarray([rt.process_id], np.int32))
        out["allgather"] = np.asarray(g).ravel().tolist()
    except Exception as e:  # noqa: BLE001 - report, judged by the test
        out["allgather_error"] = repr(e)[:300]
    print("RESULT " + json.dumps(out))
""")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _result(proc_out: str) -> dict:
    lines = [ln for ln in proc_out.splitlines()
             if ln.startswith("RESULT ")]
    assert lines, f"no RESULT line in output:\n{proc_out[-2000:]}"
    return json.loads(lines[-1][len("RESULT "):])


@pytest.mark.slow
def test_two_process_fleet_shares_one_plan_and_migrates_across_hosts():
    """ISSUE-3 acceptance: 2 ``jax.distributed`` CPU processes, one
    FleetPlan from the shared event log, cross-host migration to the
    other process's spare, merged completions bit-identical."""
    port = _free_port()
    env = dict(os.environ, PYTHONPATH=SRC)
    procs = [subprocess.Popen(
        [sys.executable, "-c", WORKER, str(pid), str(port)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True) for pid in (0, 1)]
    outs = []
    for p in procs:
        stdout, stderr = p.communicate(timeout=900)
        assert p.returncode == 0, stderr[-3000:]
        outs.append(_result(stdout))
    r0, r1 = sorted(outs, key=lambda r: r["pid"])

    # one runtime: both processes see the 4-device global fleet
    assert r0["n_global_devices"] == r1["n_global_devices"] == 4
    assert r0["allgather"] == r1["allgather"] == [0, 1]

    # one FleetPlan: the fault published by process 0 reached process 1
    # through the event log and both folded the same final plan
    assert r0["fleet_fingerprint"] == r1["fleet_fingerprint"]
    assert r0["fingerprints"] == r1["fingerprints"]
    assert len(set(r0["fingerprints"])) == 1
    for r in (r0, r1):
        assert r["quarantined"] == [0]
        assert r["spare_for_0"] == 3           # migrated to host 1's spare
        assert r["late_events"] == 0

    # migration really moved in-flight work across the process boundary:
    # requests drained from device 0 (host 0) re-admitted on device 3
    # (host 1), and every process agrees who decoded what
    assert r0["devices_by_rid"] == r1["devices_by_rid"]
    assert r0["requeued"] == r1["requeued"] > 0
    assert r0["per_device_tokens"][3] > 0
    assert 3 in set(r0["devices_by_rid"].values())

    # merged completions: complete and bit-identical to the single-host
    # reference on BOTH hosts
    assert r0["completed"] == r1["completed"] == list(range(6))
    assert r0["mismatched"] == r1["mismatched"] == []
